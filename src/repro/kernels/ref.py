"""Pure-jnp oracles for every Pallas kernel (the ground truth in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import prox as prox_mod
from repro.models import attention as attn_mod


def logistic_vjp_ref(a, b, mask, x):
    """a (N,D), b (N,1), mask (N,1), x (1,D) -> (loss (1,1), grad (1,D))."""
    m = -b * (a @ x.T)                                # (N,1)
    loss = jnp.sum(mask * jnp.logaddexp(0.0, m))
    c = mask * (-b) * jax.nn.sigmoid(m)               # (N,1)
    grad = c.T @ a                                    # (1,D)
    return loss.reshape(1, 1), grad


def svm_vjp_ref(a, b, mask, x, gamma):
    """Smoothed-hinge twin of ``logistic_vjp_ref`` (problems/svm.py's loss).
    a (N,D), b (N,1), mask (N,1), x (1,D) -> (loss (1,1), grad (1,D))."""
    m = b * (a @ x.T)                                 # (N,1)
    val = jnp.where(m >= 1.0, 0.0,
                    jnp.where(m <= 1.0 - gamma,
                              1.0 - m - gamma / 2,
                              (1.0 - m) ** 2 / (2 * gamma)))
    dldm = jnp.where(m >= 1.0, 0.0,
                     jnp.where(m <= 1.0 - gamma, -1.0, -(1.0 - m) / gamma))
    c = mask * dldm * b                               # (N,1)
    loss = jnp.sum(mask * val)
    return loss.reshape(1, 1), c.T @ a


def softmax_vjp_ref(a, y, mask, X):
    """Fused multinomial value+grad (problems/softmax.py's loss).
    a (N,D), y (N,) int labels, mask (N,1), X (D,C) -> (loss (1,1),
    grad (D,C)).  Masked rows contribute exactly zero to both."""
    C = X.shape[1]
    logits = a @ X                                    # (N, C)
    lse = jax.scipy.special.logsumexp(logits, axis=1)
    picked = jnp.take_along_axis(logits, y[:, None], axis=1)[:, 0]
    loss = jnp.sum(mask[:, 0] * (lse - picked))
    resid = mask * (jax.nn.softmax(logits, axis=1)
                    - jax.nn.one_hot(y, C, dtype=X.dtype))  # (N, C)
    return loss.reshape(1, 1), a.T @ resid


def soft_threshold_ref(omega, z_old, thr):
    """omega, z_old (1,D), thr (1,1) -> (z_new, ssq (1,1), nnz (1,1))."""
    z_new = prox_mod.soft_threshold(omega, thr[0, 0])
    diff = z_new - z_old
    ssq = jnp.sum(diff * diff).reshape(1, 1)
    nnz = jnp.sum((z_new != 0.0).astype(jnp.float32)).reshape(1, 1)
    return z_new, ssq, nnz


def flash_attention_ref(q, k, v, *, causal=True, window=None):
    """q (B,S,H,hd), k/v (B,Skv,KV,hd) -> (B,S,H,hd).  Naive oracle."""
    return attn_mod.naive_attention(q, k, v, causal=causal, window=window)


def decode_attention_ref(q, k_cache, v_cache, positions):
    """q (B,1,H,hd), caches (B,Smax,KV,hd), positions (B,) -> (B,1,H,hd)."""
    return attn_mod.decode_attention(q, k_cache, v_cache, positions)
