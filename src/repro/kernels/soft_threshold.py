"""Fused ADMM z-update Pallas TPU kernel.

Algorithm 1 lines 13-15 in one sweep over the decision vector:
  z_new = S(omega_bar; thr)              (soft threshold, prox of l1)
  s_sq  = ||z_new - z_old||^2            (dual-residual term)
  nnz   = #{z_new != 0}                  (sparsity telemetry)

Elementwise VPU work + two scalar reductions accumulated across the
(sequential) tile grid; one HBM pass instead of three.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = 8 * 1024


def _kernel(omega_ref, zold_ref, thr_ref, z_ref, ssq_ref, nnz_ref):
    i = pl.program_id(0)
    omega = omega_ref[...]                            # (1, T)
    z_old = zold_ref[...]
    thr = thr_ref[0, 0]

    mag = jnp.abs(omega)
    z_new = jnp.where(mag > thr,
                      (1.0 - thr / jnp.where(mag > 0, mag, 1.0)) * omega,
                      0.0)
    z_ref[...] = z_new

    diff = z_new - z_old
    ssq_part = jnp.sum(diff * diff)
    nnz_part = jnp.sum((z_new != 0.0).astype(jnp.float32))

    @pl.when(i == 0)
    def _init():
        ssq_ref[...] = jnp.zeros_like(ssq_ref)
        nnz_ref[...] = jnp.zeros_like(nnz_ref)

    ssq_ref[...] += ssq_part.reshape(1, 1)
    nnz_ref[...] += nnz_part.reshape(1, 1)


def _pick_block(D: int, block: int) -> int:
    """Largest 128-multiple tile <= ``block`` that DIVIDES D.  The naive
    ``min(block, D)`` is wrong whenever D exceeds the block but is not a
    multiple of it (e.g. D = 8320 vs the 8192 default) — the grid then
    needs a ragged last tile the kernel does not mask.  Walking down in
    lane-multiples always terminates at 128, which divides any padded D."""
    blk = min(block, D)
    blk -= blk % 128
    while blk > 128 and D % blk:
        blk -= 128
    return blk


def soft_threshold_pallas(omega, z_old, thr, *, block: int = DEFAULT_BLOCK,
                          interpret: bool = False):
    """omega, z_old (1, D) f32; thr (1, 1) f32; D % 128 == 0.
    Returns (z_new (1,D), ssq (1,1), nnz (1,1))."""
    _, D = omega.shape
    blk = _pick_block(D, block)
    assert D % blk == 0 and blk % 128 == 0, (D, blk)
    grid = (D // blk,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, D), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
        ],
        interpret=interpret,
    )(omega, z_old, thr)
