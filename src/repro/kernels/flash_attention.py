"""Flash-attention forward Pallas TPU kernel (causal, GQA, sliding window).

Online-softmax over KV tiles with the accumulator resident in VMEM scratch.
Grid is (B * KV, n_qrow_blocks, n_kv_blocks) with the KV axis innermost so
the (acc, m, l) scratch carries across KV steps of one (head, q-block).
Fully-masked tiles (above the causal diagonal, or outside the sliding
window) are skipped with ``pl.when`` — the MXU work performed matches the
valid-block FLOP count of the jnp oracle (models/attention.block_attention).

GQA layout: queries are passed as (B*KV, G*S, hd) — the G query heads of one
KV head are stacked along the row axis (G-major), so each grid row streams
its K/V tile exactly once for all G query heads; K/V are never materialised
repeated.  Because S % block_q == 0, every q-block lies within a single
query head and its in-sequence position is simply ``row % S``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
            *, scale: float, causal: bool, window, block_q: int,
            block_kv: int, n_kv: int, seq_q: int):
    i = pl.program_id(1)              # q-row block
    j = pl.program_id(2)              # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_lo = (i * block_q) % seq_q      # in-sequence position of the block
    k_lo = j * block_kv

    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_lo + block_q - 1)
    if window is not None:
        live = jnp.logical_and(live, k_lo + block_kv - 1 > q_lo - window)

    @pl.when(live)
    def _step():
        q = q_ref[0]                                  # (block_q, hd)
        k = k_ref[0]                                  # (block_kv, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s = s * scale                                 # (block_q, block_kv)

        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        if causal:
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        if window is not None:
            s = jnp.where(kpos > qpos - window, s, -jnp.inf)

        m_prev = m_ref[...]                           # (block_q, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        m_safe = jnp.maximum(m_new, NEG_INF)
        p = jnp.exp(s - m_safe)
        alpha = jnp.exp(jnp.maximum(m_prev, NEG_INF) - m_safe)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(j == n_kv - 1)
    def _finish():
        l = l_ref[...]
        l = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jnp.ndarray,                   # (B*KV, G*S, hd)
    k: jnp.ndarray,                   # (B*KV, Skv, hd)
    v: jnp.ndarray,                   # (B*KV, Skv, hd)
    *,
    seq_q: int,                       # S (per query head)
    causal: bool = True,
    window=None,
    block_q: int = 512,
    block_kv: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    BKV, GS, hd = q.shape
    Skv = k.shape[1]
    assert GS % seq_q == 0
    bq = min(block_q, seq_q)
    while seq_q % bq:
        bq -= 1
    bkv = min(block_kv, Skv)
    while Skv % bkv:
        bkv -= 1
    n_qrows = GS // bq
    n_kv = Skv // bkv
    scale = hd ** -0.5

    kern = functools.partial(
        _kernel, scale=scale, causal=causal, window=window,
        block_q=bq, block_kv=bkv, n_kv=n_kv, seq_q=seq_q)

    return pl.pallas_call(
        kern,
        grid=(BKV, n_qrows, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda h, i, j: (h, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda h, i, j: (h, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BKV, GS, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
