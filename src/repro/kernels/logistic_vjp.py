"""Fused logistic loss+gradient Pallas TPU kernel.

The ADMM worker's inner-loop hot spot is the FISTA gradient evaluation
  f(x)    = sum_n log(1 + exp(-b_n <a_n, x>))
  grad(x) = A^T (-b * sigmoid(-b Ax))
which naively is two full passes over A (one for Ax, one for A^T c).  This
kernel fuses both into ONE pass: for each row tile of A held in VMEM it
computes the margins (MXU matvec), the loss partial and the coefficient
vector (VPU transcendentals), and immediately applies the transposed-tile
matvec for the gradient contribution — so A is streamed from HBM exactly
once per FISTA iteration.  Loss and gradient accumulate in VMEM across the
(sequential) row-tile grid.

TPU adaptation (DESIGN.md §7): the paper's CSR-sparse rows (p=0.001) become
dense VMEM tiles — gather/scatter on the sparse structure would idle the MXU;
dense row tiles of the d<=~12k feature dim fit VMEM comfortably.

Padding contract (handled by ops.fused_logistic_vjp): rows are padded with
mask=0 (excluded from loss and grad), the feature dim with zero columns.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256 rows x 10112 padded features x 4B = ~10.4 MB VMEM.
DEFAULT_BLOCK_ROWS = 256


def _kernel(a_ref, b_ref, mask_ref, x_ref, loss_ref, grad_ref):
    i = pl.program_id(0)

    a = a_ref[...]                                   # (TN, D)
    b = b_ref[...]                                   # (TN, 1)
    mask = mask_ref[...]                             # (TN, 1)
    x = x_ref[...]                                   # (1, D)

    # margins m_n = -b_n <a_n, x>   (MXU: (TN,D) @ (D,1))
    ax = jax.lax.dot_general(a, x.T, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TN,1)
    m = -b * ax
    # loss partial: sum mask * log1p(exp(m)), stable via logaddexp
    loss_part = jnp.sum(mask * jnp.logaddexp(0.0, m))
    # coefficients c_n = -b_n * sigmoid(m_n), masked
    c = mask * (-b) * jax.nn.sigmoid(m)              # (TN,1)
    # gradient partial: A^T c  (MXU: (D,TN) @ (TN,1) -> do (1,TN)@(TN,D))
    gpart = jax.lax.dot_general(c.T, a, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1,D)

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    loss_ref[...] += loss_part.reshape(1, 1)
    grad_ref[...] += gpart


def logistic_vjp_pallas(a, b, mask, x, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = False):
    """a (N, D), b (N, 1), mask (N, 1), x (1, D); N % block_rows == 0,
    D % 128 == 0.  Returns (loss (1,1) f32, grad (1,D) f32)."""
    N, D = a.shape
    assert N % block_rows == 0 and D % 128 == 0, (N, D)
    grid = (N // block_rows,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, mask, x)
