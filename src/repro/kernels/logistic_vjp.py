"""Fused margin-loss value+gradient Pallas TPU kernels (logistic + hinge).

The ADMM worker's inner-loop hot spot is the FISTA gradient evaluation
  f(x)    = sum_n l(b_n <a_n, x>)
  grad(x) = A^T (l'(b Ax) * b)
which naively is two full passes over A (one for Ax, one for A^T c).  These
kernels fuse both into ONE pass: for each row tile of A held in VMEM they
compute the margins (MXU matvec), the loss partial and the coefficient
vector (VPU elementwise/transcendentals), and immediately apply the
transposed-tile matvec for the gradient contribution — so A is streamed
from HBM exactly once per FISTA iteration.  Loss and gradient accumulate
in VMEM across the (sequential) row-tile grid.

Two margin losses share the one kernel body (static ``loss`` switch):

  * ``logistic`` — l(m) = log(1 + exp(-m)), the paper's workload;
  * ``hinge``    — the quadratically-smoothed (Huberized) hinge of
    problems/svm.py, l_gamma(m) piecewise in (1 - m).

TPU adaptation (DESIGN.md §7): the paper's CSR-sparse rows (p=0.001) become
dense VMEM tiles — gather/scatter on the sparse structure would idle the MXU;
dense row tiles of the d<=~12k feature dim fit VMEM comfortably.

Padding contract (handled by ops.fused_*_vjp): rows are padded with
mask=0 (excluded from loss and grad), the feature dim with zero columns.
A leading worker axis batches via ``jax.vmap`` — Pallas lifts the batch
dimension onto the grid, so all W lanes run in one kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256 rows x 10112 padded features x 4B = ~10.4 MB VMEM.
DEFAULT_BLOCK_ROWS = 256


def _margin_kernel(loss: str, gamma: float,
                   a_ref, b_ref, mask_ref, x_ref, loss_ref, grad_ref):
    i = pl.program_id(0)

    a = a_ref[...]                                   # (TN, D)
    b = b_ref[...]                                   # (TN, 1)
    mask = mask_ref[...]                             # (TN, 1)
    x = x_ref[...]                                   # (1, D)

    # signed activation <a_n, x>   (MXU: (TN,D) @ (D,1))
    ax = jax.lax.dot_general(a, x.T, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (TN,1)
    if loss == "logistic":
        # l(m) = log1p(exp(-m)) at m = b*ax; stable via logaddexp
        neg_m = -b * ax
        val = jnp.logaddexp(0.0, neg_m)
        dldax = (-b) * jax.nn.sigmoid(neg_m)         # d l / d ax
    elif loss == "hinge":
        # smoothed hinge (Rennie & Srebro '05), gamma the smoothing width
        m = b * ax
        val = jnp.where(m >= 1.0, 0.0,
                        jnp.where(m <= 1.0 - gamma,
                                  1.0 - m - gamma / 2,
                                  (1.0 - m) ** 2 / (2 * gamma)))
        dldm = jnp.where(m >= 1.0, 0.0,
                         jnp.where(m <= 1.0 - gamma,
                                   -1.0, -(1.0 - m) / gamma))
        dldax = dldm * b
    else:  # pragma: no cover - static arg, guarded by the wrappers
        raise ValueError(f"unknown margin loss {loss!r}")

    loss_part = jnp.sum(mask * val)
    c = mask * dldax                                 # (TN,1)
    # gradient partial: A^T c  (MXU: (1,TN) @ (TN,D))
    gpart = jax.lax.dot_general(c.T, a, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (1,D)

    @pl.when(i == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)
        grad_ref[...] = jnp.zeros_like(grad_ref)

    loss_ref[...] += loss_part.reshape(1, 1)
    grad_ref[...] += gpart


def _margin_vjp_pallas(a, b, mask, x, *, loss: str, gamma: float,
                       block_rows: int, interpret: bool):
    N, D = a.shape
    assert N % block_rows == 0 and D % 128 == 0, (N, D)
    grid = (N // block_rows,)
    return pl.pallas_call(
        functools.partial(_margin_kernel, loss, gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, D), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, D), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, 1), jnp.float32),
            jax.ShapeDtypeStruct((1, D), jnp.float32),
        ],
        interpret=interpret,
    )(a, b, mask, x)


def logistic_vjp_pallas(a, b, mask, x, *, block_rows: int = DEFAULT_BLOCK_ROWS,
                        interpret: bool = False):
    """a (N, D), b (N, 1), mask (N, 1), x (1, D); N % block_rows == 0,
    D % 128 == 0.  Returns (loss (1,1) f32, grad (1,D) f32)."""
    return _margin_vjp_pallas(a, b, mask, x, loss="logistic", gamma=0.0,
                              block_rows=block_rows, interpret=interpret)


def svm_vjp_pallas(a, b, mask, x, *, gamma: float,
                   block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = False):
    """Smoothed-hinge twin of ``logistic_vjp_pallas`` (problems/svm.py's
    loss); same shape/padding contract, ``gamma`` the smoothing width."""
    return _margin_vjp_pallas(a, b, mask, x, loss="hinge", gamma=gamma,
                              block_rows=block_rows, interpret=interpret)
